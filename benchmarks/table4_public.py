"""Paper Table 4: public-dataset choice (TinyImageNet/LSUN/Uniform-Noise →
our aligned/shifted/noise) — IDKD must stay ahead of vanilla KD on every
public set because the OoD detector selects the aligned subset."""
from __future__ import annotations

import time

from benchmarks.common import mean_std, run_cell

KINDS = ["aligned", "shifted", "noise"]
METHODS = ["qg-dsgdm-n+kd", "qg-idkd"]


def run(alpha: float = 0.05, nodes: int = 8, seeds=(4,)):
    rows, csv = [], []
    for method in METHODS:
        row = {"method": method}
        for kind in KINDS:
            t0 = time.time()
            cells = [run_cell(method, alpha, nodes=nodes, public_kind=kind,
                              seed=s) for s in seeds]
            row[kind] = mean_std(cells)
            row[f"{kind}/id_frac"] = f"{cells[0]['id_fraction']:.2f}"
            csv.append((f"table4/{method}/{kind}", (time.time() - t0) * 1e6,
                        f"acc={cells[0]['final_acc']*100:.2f}"))
        rows.append(row)
    return rows, csv


def render(rows) -> str:
    cols = list(rows[0].keys())
    lines = [" | ".join(cols), " | ".join(["---"] * len(cols))]
    for r in rows:
        lines.append(" | ".join(str(r[c]) for c in cols))
    return "\n".join(lines)


if __name__ == "__main__":
    print(render(run()[0]))
