"""Bench regression guard: fresh BENCH_*.json vs the committed baseline.

Extracts every named hot-path metric (``us_per_step`` / ``us_per_call`` /
``wall_s`` / ``bytes_per_step`` leaves, named by the string fields of
their enclosing cell) from both documents and fails when any shared
metric slowed down by more than ``--threshold`` (default 1.5×).
Timing cells gate on the **p50**: ``us_per_step`` is the median over
interleaved bench rounds (``bench_driver._median_rates``); the
``us_per_step_p95`` tail-latency field rides along in the BENCH cells
for visibility but is deliberately not in ``METRIC_KEYS`` — p95 on a
shared CI box is noise-dominated and would flake the guard.
``bytes_per_step`` guards the *wire*, not the clock: a compressed-gossip
cell (labels ``compression=topk:0.01|gossip=...``) regressing its byte
count means the sparsifier stopped sparsifying. Metrics present in only one of
{fresh, committed} are *always* skipped (reported, never failed) —
benches are allowed to grow cells, and cells keyed by environment labels
(e.g. the sharded driver's ``devices=8`` rows, measured under a forced
8-device mesh, or the 2-D federation-mesh rows keyed by their
``mesh=4x2``-style shape string) legitimately exist on one side when
the other ran in a different environment; a shape the current pool
can't factor simply doesn't appear. String fields like ``mesh`` join a
cell's name automatically — no schema change needed here when a bench
grows a new label column. The only hard failure besides a real slowdown is
the two documents sharing *no* metrics at all before ``--include``
filtering — that means schema/label drift left the guard checking
nothing; an ``--include`` regex that happens to match only one-sided
cells merely reports that nothing matched.

    python -m benchmarks.check_regression \
        --baseline BENCH_driver.json --fresh /tmp/BENCH_driver.json \
        [--threshold 1.5] [--include 'scan|host']
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict

# gated metrics: medians (p50) only — us_per_step_p95 is recorded in the
# BENCH cells but intentionally absent here (tail latency is informative,
# not gateable, on shared CI hardware)
METRIC_KEYS = ("us_per_step", "us_per_call", "us_per_round", "wall_s",
               "bytes_per_step")


def extract_metrics(doc, metric_keys=METRIC_KEYS) -> Dict[str, float]:
    """name -> value for every metric leaf. A cell's name is built from
    its own string/bool fields (order-stable), so it survives list
    reordering between bench runs."""
    out: Dict[str, float] = {}

    def walk(node, path):
        if isinstance(node, dict):
            labels = "|".join(
                f"{k}={node[k]}" for k in sorted(node)
                if isinstance(node[k], (str, bool)) or
                (isinstance(node[k], int) and k not in metric_keys))
            for k in sorted(node):
                v = node[k]
                if k in metric_keys and isinstance(v, (int, float)):
                    name = "/".join([p for p in path if p] + [labels, k])
                    while name in out:       # collisions get a suffix
                        name += "+"
                    out[name] = float(v)
                elif isinstance(v, (dict, list)):
                    walk(v, path + [k])
        elif isinstance(node, list):
            for v in node:
                walk(v, path)

    walk(doc, [])
    return out


def compare(baseline: Dict[str, float], fresh: Dict[str, float],
            threshold: float, include: str = "") -> int:
    """Print the comparison; return the number of failures (>threshold
    slowdowns, or 1 when the documents share no metrics at all)."""
    pat = re.compile(include) if include else None
    shared_all = sorted(set(baseline) & set(fresh))
    shared = ([n for n in shared_all if pat.search(n)] if pat is not None
              else shared_all)
    regressions = 0
    for name in shared:
        base, new = baseline[name], fresh[name]
        ratio = new / base if base > 0 else float("inf") if new > 0 else 1.0
        flag = ""
        if ratio > threshold:
            regressions += 1
            flag = f"  << REGRESSION (> {threshold:.2f}x)"
        print(f"{name}: {base:.1f} -> {new:.1f} ({ratio:.2f}x){flag}")
    skipped = sorted(set(baseline) ^ set(fresh))
    for name in skipped:
        side = "baseline" if name in baseline else "fresh"
        print(f"{name}: only in {side} (skipped)")
    if skipped:
        print(f"({len(skipped)} one-sided cell(s) skipped, never failed)")
    if not shared_all:
        # schema/label drift must fail loudly, not leave CI green with a
        # guard that checks nothing
        print("ERROR: no shared metrics between baseline and fresh "
              "documents — refresh the committed baseline")
        return 1
    if not shared:
        print(f"note: --include {include!r} matched no shared metric "
              "(only one-sided cells); nothing to check")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--threshold", type=float, default=1.5)
    ap.add_argument("--include", default="",
                    help="regex filter on metric names (default: all)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        base = extract_metrics(json.load(f))
    with open(args.fresh) as f:
        fresh = extract_metrics(json.load(f))
    bad = compare(base, fresh, args.threshold, args.include)
    # the summary line carries the one-sided count so a CI log's last
    # line says both what failed and what was never compared
    n_skipped = len(set(base) ^ set(fresh))
    note = (f", {n_skipped} one-sided cell(s) skipped" if n_skipped else "")
    if bad:
        print(f"\nbench regression guard failed ({bad} issue(s), "
              f"threshold {args.threshold:.2f}x{note})")
        sys.exit(1)
    print(f"\nno bench regressions (p50-gated{note})")


if __name__ == "__main__":
    main()
