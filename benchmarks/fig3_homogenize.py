"""Paper Figure 3: (a) class-distribution homogenization pre/post IDKD,
(b) convergence curves IDKD vs QG-DSGDm-N."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_cell
from repro.core.idkd import skew_metric
import jax.numpy as jnp


def run(alpha: float = 0.1, nodes: int = 8, seed: int = 4):
    cell = run_cell("qg-idkd", alpha, nodes=nodes, seed=seed)
    base = run_cell("qg-dsgdm-n", alpha, nodes=nodes, seed=seed)
    pre = np.asarray(cell["pre_hist"])
    post = np.asarray(cell["post_hist"])
    pre_skew = float(skew_metric(jnp.asarray(pre)))
    post_skew = float(skew_metric(jnp.asarray(post)))
    rows = [{
        "metric": "mean TV-from-uniform (skew)",
        "pre-IDKD": f"{pre_skew:.3f}", "post-IDKD": f"{post_skew:.3f}",
        "node0 empty classes pre": int((pre[0] == 0).sum()),
        "node0 empty classes post": int((post[0] < 1e-6).sum()),
    }]
    csv = [("fig3a/skew_pre", 0.0, f"{pre_skew:.4f}"),
           ("fig3a/skew_post", 0.0, f"{post_skew:.4f}"),
           ("fig3b/final_acc_idkd", 0.0, f"{cell['final_acc']*100:.2f}"),
           ("fig3b/final_acc_qgm", 0.0, f"{base['final_acc']*100:.2f}")]
    return rows, csv, {"idkd_curve": cell["acc_history"],
                       "qgm_curve": base["acc_history"]}


if __name__ == "__main__":
    rows, _, curves = run()
    print(rows[0])
    print("idkd:", curves["idkd_curve"])
    print("qgm :", curves["qgm_curve"])
