"""Paper Table 6: communication cost in MiB per iteration.

Gossip parameter exchange dominates; IDKD adds only the (amortized) label
payload — the paper reports ~2% overhead. Computed analytically from the
measured run metadata (param count × degree + labels/steps), plus the
LLM-scale projection with top-k sparse labels (beyond-paper codec)."""
from __future__ import annotations

from benchmarks.common import run_cell
from repro.configs import get_config
from repro.core.distill import label_bytes

MIB = 1024 ** 2


def run(alpha: float = 0.1, nodes: int = 8, seeds=(4,)):
    rows, csv = [], []
    base = run_cell("qg-dsgdm-n", alpha, nodes=nodes, seed=seeds[0])
    idkd = run_cell("qg-idkd", alpha, nodes=nodes, seed=seeds[0])
    base_mib = base["comm_bytes_per_iter"] / MIB
    idkd_mib = (idkd["comm_bytes_per_iter"]
                + idkd["label_bytes_total"] / idkd["steps"]) / MIB
    rows.append({"method": "QG-DSGDm-N", "MiB/iter": f"{base_mib:.4f}"})
    rows.append({"method": "QG-IDKD (ours)", "MiB/iter": f"{idkd_mib:.4f}",
                 "overhead": f"{(idkd_mib/base_mib - 1)*100:.2f}%"})
    csv.append(("table6/overhead_pct", 0.0,
                f"{(idkd_mib/base_mib - 1)*100:.3f}"))

    # LLM-scale projection: per-iteration gossip of a 1.7B model vs one
    # label exchange of 4096 public sequences × 64 tokens, top-8 sparse,
    # amortized over 1000 iterations between exchanges.
    cfg = get_config("qwen3-1.7b")
    gossip = 2 * cfg.param_count() * 2 / MIB          # 2 neighbours, bf16
    dense_lbl = label_bytes(4096 * 64, cfg.vocab_size) / 1000 / MIB
    topk_lbl = label_bytes(4096 * 64, cfg.vocab_size, topk=8) / 1000 / MIB
    rows.append({"method": "qwen3-1.7b gossip", "MiB/iter": f"{gossip:.1f}"})
    rows.append({"method": "+dense labels (paper codec)",
                 "MiB/iter": f"{gossip + dense_lbl:.1f}",
                 "overhead": f"{dense_lbl/gossip*100:.1f}%"})
    rows.append({"method": "+top-8 sparse labels (ours)",
                 "MiB/iter": f"{gossip + topk_lbl:.1f}",
                 "overhead": f"{topk_lbl/gossip*100:.3f}%"})
    csv.append(("table6/llm_topk_overhead_pct", 0.0,
                f"{topk_lbl/gossip*100:.4f}"))
    return rows, csv


def render(rows) -> str:
    cols = ["method", "MiB/iter", "overhead"]
    lines = [" | ".join(cols), " | ".join(["---"] * len(cols))]
    for r in rows:
        lines.append(" | ".join(str(r.get(c, "")) for c in cols))
    return "\n".join(lines)


if __name__ == "__main__":
    print(render(run()[0]))
