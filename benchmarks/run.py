"""Benchmark orchestrator — one section per paper table.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and the
rendered markdown tables. Results are cached under experiments/bench/, so
re-runs are incremental.

    PYTHONPATH=src python -m benchmarks.run            # all sections
    PYTHONPATH=src python -m benchmarks.run --only table2,roofline
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

sys.path.insert(0, "src")

from benchmarks import (bench_driver, bench_kernels,  # noqa: E402
                        bench_schedule, fig3_homogenize, roofline,
                        table2_noniid, table3_topology, table4_public,
                        table6_comm, table7_scale)

SECTIONS = {
    "table2": lambda: table2_noniid.run(),
    "table3": lambda: table3_topology.run(),
    "table4": lambda: table4_public.run(),
    "table6": lambda: table6_comm.run(),
    "table7": lambda: table7_scale.run(),
    "fig3": lambda: fig3_homogenize.run()[:2],
    "kernels": lambda: bench_kernels.run(),
    "labeling": lambda: bench_kernels.bench_labeling(),
    "driver": lambda: bench_driver.run(),
    "schedule": lambda: bench_schedule.run(),
    "roofline": lambda: roofline.run(),
}

RENDERERS = {
    "table2": table2_noniid.render,
    "table3": table3_topology.render,
    "table4": table4_public.render,
    "table6": table6_comm.render,
    "table7": table7_scale.render,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(SECTIONS))
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        t0 = time.time()
        try:
            rows, csv = SECTIONS[name]()
        except Exception:  # noqa: BLE001 — keep the report going
            traceback.print_exc()
            failures.append(name)
            continue
        for row in csv:
            print(",".join(str(x) for x in row), flush=True)
        if name in RENDERERS and rows:
            print(f"\n## {name}\n{RENDERERS[name](rows)}\n", flush=True)
        elif rows:
            print(f"\n## {name}\n{rows}\n", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()
