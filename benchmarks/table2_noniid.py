"""Paper Table 2/5: accuracy vs Dirichlet α for the method grid on a ring.

Methods: DSGD, QG-DSGDm-N, QG-DSGDm-N+KD (vanilla), QG-IDKD (ours),
SGD-Centralized (IID upper bound). Synthetic CIFAR-stand-in (DESIGN.md §3);
validation is directional against the paper's ordering:
    IDKD > vanilla KD ≥ QG-DSGDm-N > DSGD at high skew (α = 0.05),
    gaps shrinking as α grows.
"""
from __future__ import annotations

import time

from benchmarks.common import mean_std, run_cell

METHODS = ["dsgd", "qg-dsgdm-n", "qg-dsgdm-n+kd", "qg-idkd",
           "sgd-centralized"]
ALPHAS = [1.0, 0.1, 0.05]


def run(nodes: int = 8, seeds=(4,), quick: bool = True):
    rows = []
    csv = []
    for method in METHODS:
        row = {"method": method}
        for alpha in ALPHAS:
            t0 = time.time()
            cells = [run_cell(method, alpha, nodes=nodes, seed=s)
                     for s in seeds]
            row[f"alpha={alpha}"] = mean_std(cells)
            csv.append((f"table2/{method}/alpha{alpha}",
                        (time.time() - t0) * 1e6 / max(cells[0]['steps'], 1),
                        f"acc={cells[0]['final_acc']*100:.2f}"))
        rows.append(row)
    return rows, csv


def render(rows) -> str:
    cols = ["method"] + [f"alpha={a}" for a in ALPHAS]
    lines = [" | ".join(cols), " | ".join(["---"] * len(cols))]
    for r in rows:
        lines.append(" | ".join(str(r[c]) for c in cols))
    return "\n".join(lines)


if __name__ == "__main__":
    rows, _ = run()
    print(render(rows))
