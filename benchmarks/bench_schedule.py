"""Federation scheduler benchmark: round count × scenario grid.

Times the scheduler-driven simulator (``repro.sched`` + ``core.driver``)
end to end for {1, 4, 16}-round schedules × {static ring, churn, rewire,
compressed} scenarios at the bench_driver node scale, and records each
run's per-round communication ledger (wire-dtype-aware param gossip +
label payload bytes, per node per round). The ``compressed`` scenario
runs top-k 1% delayed gossip with a mid-run straggler (DESIGN.md §9);
its ``bytes_per_step`` cell lets the regression guard watch the
sparsified wire. Writes ``BENCH_schedule.json``.

The interesting ratios:

* ``us_per_step`` across round counts — what a 16× rehomogenization
  schedule costs over one-shot IDKD (labeling rounds + sampler ctx
  refreshes; the ctx rides through one compiled runner, so extra rounds
  cost labeling work, not recompiles);
* churn / rewire vs static — the masked-mixer / remade-step compiles are
  cached per availability mask, so a down-up cycle costs two compiles,
  not one per chunk.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro import sched
from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core.simulator import DecentralizedSimulator
from repro.data.synthetic import make_classification_data, make_public_data

NODES = 8
STEPS = 36
EVAL_EVERY = 18
START = 2          # first homogenization step
ROUND_GRID = (1, 4, 16)
SCENARIOS = ("static_ring", "churn", "rewire", "compressed")


def _scenario_events(name: str):
    if name == "static_ring":
        return ()
    if name == "churn":
        # one node drops for the middle third, another straggles briefly
        return (sched.ChurnEvent(step=STEPS // 3, down=(NODES - 1,)),
                sched.ChurnEvent(step=2 * STEPS // 3, up=(NODES - 1,)))
    if name == "rewire":
        return (sched.RewireEvent(step=STEPS // 2, topology="exponential"),)
    if name == "compressed":
        # top-k 1% delayed gossip with a mid-run straggler whose frozen
        # payload keeps its neighbours mixing (DESIGN.md §9)
        return (sched.ChurnEvent(step=STEPS // 3, down=(NODES - 1,),
                                 mode="stale"),
                sched.ChurnEvent(step=2 * STEPS // 3, up=(NODES - 1,)))
    raise ValueError(name)


def _make_sim(rounds: int, scenario: str = ""):
    data = make_classification_data(image_size=8, n_train=1024, n_val=64,
                                    n_test=128, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=256, kind="aligned", seed=1)
    mcfg = SMALL_CONFIG.replace(image_size=8, cnn_stages=(1, 1, 1),
                                cnn_width=8)
    every_k = sched.fit_every_k(STEPS - 2, START, rounds)
    comp = (dict(compression="topk", compression_frac=0.01,
                 gossip="delayed") if scenario == "compressed" else {})
    tcfg = TrainConfig(num_nodes=NODES, steps=STEPS, batch_size=16, seed=4,
                       idkd=IDKDConfig(start_step=START, temperature=10.0,
                                       every_k_steps=every_k,
                                       num_rounds=rounds), **comp)
    return DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                  eval_every=EVAL_EVERY)


def _cell(scenario: str, rounds: int):
    sim = _make_sim(rounds, scenario)
    schedule = sched.compile_schedule(
        STEPS, EVAL_EVERY,
        round_steps=sim.default_schedule().round_steps,
        events=_scenario_events(scenario),
        gossip=sim.tcfg.gossip)
    r = sim.run(schedule=schedule)          # warm-up: compiles + first run
    t0 = time.time()
    r = sim.run(schedule=schedule)
    wall = time.time() - t0
    return {
        "scenario": scenario,
        "rounds_requested": rounds,
        "rounds_fired": len(r.rounds),
        "us_per_step": round(wall / STEPS * 1e6, 1),
        "wall_s": round(wall, 3),
        "final_acc": round(r.final_acc, 4),
        "gossip_bytes": r.ledger["gossip_bytes"],
        "bytes_per_step": round(r.ledger["gossip_bytes"] / STEPS, 1),
        "label_bytes": r.ledger["label_bytes"],
        "compression": sim.tcfg.compression,
        "gossip": sim.tcfg.gossip,
        "per_round": r.ledger["per_round"],
    }


def run(out_path: str | None = "BENCH_schedule.json"):
    csv, cells = [], []
    for scenario in SCENARIOS:
        for rounds in ROUND_GRID:
            cell = _cell(scenario, rounds)
            cells.append(cell)
            name = f"schedule/{scenario}_r{rounds}"
            csv.append((name, cell["us_per_step"],
                        f"{cell['rounds_fired']} rounds, "
                        f"{cell['label_bytes']/1e3:.1f}kB labels"))
    if out_path:
        with open(out_path, "w") as f:
            json.dump({"meta": {
                "nodes": NODES, "steps": STEPS,
                "eval_every": EVAL_EVERY,
                "round_grid": list(ROUND_GRID),
                "scenarios": list(SCENARIOS),
                "jax_backend": jax.default_backend(),
                "what": ("scheduler-driven simulator µs/step (second run "
                         "after warm-up) per {rounds}×{scenario} cell, "
                         "with the per-round communication ledger "
                         "(param-gossip + label payload bytes per node)")},
                "cells": cells}, f, indent=2)
            f.write("\n")
    return [], csv


if __name__ == "__main__":
    for row in run()[1]:
        print(",".join(str(x) for x in row))
