"""Paper Table 7: scalability in the number of nodes (ring)."""
from __future__ import annotations

import time

from benchmarks.common import mean_std, run_cell

NODES = [8, 16]
METHODS = ["qg-dsgdm-n", "qg-idkd"]


def run(alpha: float = 0.05, seeds=(4,)):
    rows, csv = [], []
    for method in METHODS:
        row = {"method": method}
        for n in NODES:
            t0 = time.time()
            cells = [run_cell(method, alpha, nodes=n, seed=s) for s in seeds]
            row[f"ring{n}"] = mean_std(cells)
            csv.append((f"table7/{method}/n{n}", (time.time() - t0) * 1e6,
                        f"acc={cells[0]['final_acc']*100:.2f}"))
        rows.append(row)
    return rows, csv


def render(rows) -> str:
    cols = list(rows[0].keys())
    lines = [" | ".join(cols), " | ".join(["---"] * len(cols))]
    for r in rows:
        lines.append(" | ".join(str(r[c]) for c in cols))
    return "\n".join(lines)


if __name__ == "__main__":
    print(render(run()[0]))
